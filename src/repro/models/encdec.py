"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, T_enc, D) — what the two conv1d+GELU layers
of Whisper would produce from the log-mel spectrogram. Encoder is
bidirectional, decoder is causal with cross-attention; norms are LayerNorm
(whisper), positional embeddings are learned params, embeddings are tied.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import (chunked_attention, decode_attention, layer_norm,
                     plain_mlp)
from .transformer import mask_padded_vocab
from .sharding import constrain

Params = dict[str, Any]

DEC_POS_MAX = 32768  # covers decode_32k; long_500k skipped (full attention)


def init_encdec_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> Params:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV, F = cfg.eff_heads, cfg.eff_kv, cfg.d_ff
    Le, Ld = cfg.encdec.num_encoder_layers, cfg.num_layers
    T_enc = cfg.encdec.encoder_seq
    ks = iter(jax.random.split(key, 24))
    s_d = 1.0 / math.sqrt(D)

    def attn(L, kdim=D):
        sk = 1.0 / math.sqrt(kdim)
        return {
            "wq": jax.random.normal(next(ks), (L, D, H, hd), dtype) * s_d,
            "wk": jax.random.normal(next(ks), (L, kdim, KV, hd), dtype) * sk,
            "wv": jax.random.normal(next(ks), (L, kdim, KV, hd), dtype) * sk,
            "wo": jax.random.normal(next(ks), (L, H, hd, D), dtype)
                  * (1.0 / math.sqrt(H * hd)),
        }

    def lnp(L, width=D):
        return {"w": jnp.ones((L, width), dtype), "b": jnp.zeros((L, width), dtype)}

    def mlp(L):
        return {
            "wi": jax.random.normal(next(ks), (L, D, F), dtype) * s_d,
            "wd": jax.random.normal(next(ks), (L, F, D), dtype)
                  * (1.0 / math.sqrt(F)),
        }

    return {
        "embed": jax.random.normal(next(ks), (cfg.padded_vocab, D), dtype),
        "enc_pos": jax.random.normal(next(ks), (T_enc, D), dtype) * 0.01,
        "dec_pos": jax.random.normal(next(ks), (DEC_POS_MAX, D), dtype) * 0.01,
        "encoder": {"attn": attn(Le), "mlp": mlp(Le),
                    "ln1": lnp(Le), "ln2": lnp(Le)},
        "enc_final_ln": {"w": jnp.ones((D,), dtype), "b": jnp.zeros((D,), dtype)},
        "decoder": {"self_attn": attn(Ld), "cross_attn": attn(Ld),
                    "mlp": mlp(Ld), "ln1": lnp(Ld), "ln2": lnp(Ld),
                    "ln3": lnp(Ld)},
        "dec_final_ln": {"w": jnp.ones((D,), dtype), "b": jnp.zeros((D,), dtype)},
    }


def _mha(cfg, p, xq, xkv, q_positions, k_positions, causal):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"])
    q = constrain(q, ("batch", None, "heads", "head_dim"))
    out = chunked_attention(q, k, v, causal=causal, q_positions=q_positions,
                            k_positions=k_positions)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode(cfg: ArchConfig, params: Params, frames: jax.Array, *,
           remat: str = "full") -> jax.Array:
    """frames: (B, T_enc, D) precomputed (conv-stub output)."""
    from .transformer import _maybe_remat

    B, T, D = frames.shape
    x = frames + params["enc_pos"][None, :T].astype(frames.dtype)
    x = constrain(x, ("batch", None, "residual"))
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(carry, layer_p):
        h = layer_norm(carry, layer_p["ln1"]["w"], layer_p["ln1"]["b"])
        x = carry + _mha(cfg, layer_p["attn"], h, h, positions, positions,
                         causal=False)
        h = layer_norm(x, layer_p["ln2"]["w"], layer_p["ln2"]["b"])
        x = x + plain_mlp(h, layer_p["mlp"]["wi"], layer_p["mlp"]["wd"], "gelu")
        return constrain(x, ("batch", None, "residual")), None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layer_norm(x, params["enc_final_ln"]["w"], params["enc_final_ln"]["b"])


def decode_train(cfg: ArchConfig, params: Params, enc_out: jax.Array,
                 tokens: jax.Array, *, remat: str = "full") -> jax.Array:
    """Teacher-forced decoder forward -> logits (B, S, V)."""
    from .transformer import _maybe_remat

    B, S = tokens.shape
    T = enc_out.shape[1]
    x = params["embed"][tokens] + params["dec_pos"][None, :S].astype(
        params["embed"].dtype)
    x = constrain(x, ("batch", None, "residual"))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    enc_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(carry, layer_p):
        h = layer_norm(carry, layer_p["ln1"]["w"], layer_p["ln1"]["b"])
        x = carry + _mha(cfg, layer_p["self_attn"], h, h, positions, positions,
                         causal=True)
        h = layer_norm(x, layer_p["ln2"]["w"], layer_p["ln2"]["b"])
        x = x + _mha(cfg, layer_p["cross_attn"], h, enc_out, positions,
                     enc_positions, causal=False)
        h = layer_norm(x, layer_p["ln3"]["w"], layer_p["ln3"]["b"])
        x = x + plain_mlp(h, layer_p["mlp"]["wi"], layer_p["mlp"]["wd"], "gelu")
        return constrain(x, ("batch", None, "residual")), None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = layer_norm(x, params["dec_final_ln"]["w"], params["dec_final_ln"]["b"])
    logits = mask_padded_vocab(cfg, jnp.einsum("bsd,vd->bsv", x, params["embed"]))
    return constrain(logits, ("batch", None, "vocab"))


def encdec_forward(cfg: ArchConfig, params: Params, frames: jax.Array,
                   tokens: jax.Array, *, remat: str = "full") -> jax.Array:
    enc_out = encode(cfg, params, frames, remat=remat)
    return decode_train(cfg, params, enc_out, tokens, remat=remat)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def encdec_cache_spec(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    hd, KV, Ld = cfg.resolved_head_dim, cfg.eff_kv, cfg.num_layers
    T = cfg.encdec.encoder_seq
    return {
        "self_k": jax.ShapeDtypeStruct((Ld, batch, max_len, KV, hd), dtype),
        "self_v": jax.ShapeDtypeStruct((Ld, batch, max_len, KV, hd), dtype),
        "cross_k": jax.ShapeDtypeStruct((Ld, batch, T, KV, hd), dtype),
        "cross_v": jax.ShapeDtypeStruct((Ld, batch, T, KV, hd), dtype),
    }


def encdec_prefill(cfg: ArchConfig, params: Params, frames: jax.Array,
                   tokens: jax.Array, *, remat: str = "full"):
    """Encode audio + teacher-forced prompt pass; returns (logits, cache)."""
    enc_out = encode(cfg, params, frames, remat=remat)
    B, S = tokens.shape
    T = enc_out.shape[1]
    x = params["embed"][tokens] + params["dec_pos"][None, :S].astype(
        params["embed"].dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    enc_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(carry, layer_p):
        x = carry
        h = layer_norm(x, layer_p["ln1"]["w"], layer_p["ln1"]["b"])
        sp = layer_p["self_attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, sp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, sp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, sp["wv"])
        attn = chunked_attention(q, k, v, causal=True, q_positions=positions,
                                 k_positions=positions)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, sp["wo"])
        h = layer_norm(x, layer_p["ln2"]["w"], layer_p["ln2"]["b"])
        cp = layer_p["cross_attn"]
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, cp["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, cp["wv"])
        cq = jnp.einsum("bsd,dhk->bshk", h, cp["wq"])
        cattn = chunked_attention(cq, ck, cv, causal=False,
                                  q_positions=positions,
                                  k_positions=enc_positions)
        x = x + jnp.einsum("bshk,hkd->bsd", cattn, cp["wo"])
        h = layer_norm(x, layer_p["ln3"]["w"], layer_p["ln3"]["b"])
        x = x + plain_mlp(h, layer_p["mlp"]["wi"], layer_p["mlp"]["wd"], "gelu")
        return x, (k, v, ck, cv)

    x, (sk, sv, ck, cv) = jax.lax.scan(body, x, params["decoder"])
    x = layer_norm(x, params["dec_final_ln"]["w"], params["dec_final_ln"]["b"])
    logits = mask_padded_vocab(cfg, jnp.einsum("bsd,vd->bsv", x, params["embed"]))
    return logits, {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}


def encdec_decode(cfg: ArchConfig, params: Params, cache: Params,
                  tokens: jax.Array, position: jax.Array):
    """One decoder step against self- and cross-KV caches."""
    B = tokens.shape[0]
    S_max = cache["self_k"].shape[2]
    T = cache["cross_k"].shape[2]
    x = params["embed"][tokens]
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], position, 1,
                                         axis=0)[None].astype(x.dtype)
    pos_b = jnp.broadcast_to(position[None], (B,)).astype(jnp.int32)
    k_positions = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None],
                                   (B, S_max))
    c_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    far = jnp.full((B,), T + 1, jnp.int32)  # cross-attn: no causal mask

    def body(carry, inputs):
        x = carry
        layer_p, sk, sv, ck, cv = inputs
        h = layer_norm(x, layer_p["ln1"]["w"], layer_p["ln1"]["b"])
        sp = layer_p["self_attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, sp["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", h, sp["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", h, sp["wv"])
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k_new.astype(sk.dtype),
                                                 position, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v_new.astype(sv.dtype),
                                                 position, axis=1)
        attn = decode_attention(q, sk, sv, position=pos_b,
                                k_positions=k_positions)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, sp["wo"])
        h = layer_norm(x, layer_p["ln2"]["w"], layer_p["ln2"]["b"])
        cp = layer_p["cross_attn"]
        cq = jnp.einsum("bsd,dhk->bshk", h, cp["wq"])
        cattn = decode_attention(cq, ck, cv, position=far,
                                 k_positions=c_positions)
        x = x + jnp.einsum("bshk,hkd->bsd", cattn, cp["wo"])
        h = layer_norm(x, layer_p["ln3"]["w"], layer_p["ln3"]["b"])
        x = x + plain_mlp(h, layer_p["mlp"]["wi"], layer_p["mlp"]["wd"], "gelu")
        return x, (sk, sv)

    x, (new_sk, new_sv) = jax.lax.scan(
        body, x, (params["decoder"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = layer_norm(x, params["dec_final_ln"]["w"], params["dec_final_ln"]["b"])
    logits = mask_padded_vocab(cfg, jnp.einsum("bsd,vd->bsv", x, params["embed"]))
    new_cache = dict(cache)
    new_cache["self_k"], new_cache["self_v"] = new_sk, new_sv
    return logits, new_cache
