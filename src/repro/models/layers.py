"""Shared neural building blocks: norms, RoPE, gated MLPs, chunked attention.

Everything is functional (params are explicit pytrees) and shape-polymorphic
enough to be used both concrete (smoke tests) and abstract (dry-run lowering
on 512 placeholder devices). Attention is *chunked* with an online-softmax
scan over KV blocks so 32k-token prefill lowers with bounded live memory —
the jnp expression of the flash-attention schedule (the Pallas splash kernel
would slot in here on real hardware; on this CPU container the chunked-jnp
form is what we can validate and cost-analyse).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             zero_centered: bool = False) -> jax.Array:
    """RMSNorm in fp32 (gemma-style ``(1+w)`` when zero_centered)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    x32 = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    out = x32 * (1.0 + w) if zero_centered else x32 * w
    return out.astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                   # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------


def _activate(x: jax.Array, kind: str) -> jax.Array:
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if kind in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


def gated_mlp(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
              activation: str) -> jax.Array:
    """(B,S,D) -> (B,S,D) with gate/up (D,F) and down (F,D)."""
    gate = _activate(jnp.einsum("bsd,df->bsf", x, wg), activation)
    up = jnp.einsum("bsd,df->bsf", x, wu)
    return jnp.einsum("bsf,fd->bsd", gate * up, wd)


def plain_mlp(x: jax.Array, wi: jax.Array, wd: jax.Array,
              activation: str = "gelu") -> jax.Array:
    h = _activate(jnp.einsum("bsd,df->bsf", x, wi), activation)
    return jnp.einsum("bsf,fd->bsd", h, wd)


# ---------------------------------------------------------------------------
# attention — chunked online-softmax over KV blocks (GQA-native)
# ---------------------------------------------------------------------------


def _kv_chunks(seq: int, target: int) -> int:
    """Largest divisor of ``seq`` that is <= target (static shapes for scan)."""
    target = min(seq, target)
    for c in range(target, 0, -1):
        if seq % c == 0:
            return c
    return seq


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool,
                      q_positions: jax.Array,
                      k_positions: jax.Array,
                      scale: float | None = None,
                      kv_chunk: int = 1024,
                      logit_softcap: float | None = None) -> jax.Array:
    """GQA attention without materializing (Sq, Sk) for the full KV length.

    q: (B, Sq, H, hd) — H query heads
    k, v: (B, Sk, KV, hd) — KV heads; H % KV == 0 (GQA groups = H // KV)
    positions: (B, Sq) / (B, Sk) absolute positions (mask = qpos >= kpos)
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd) * jnp.asarray(scale, q.dtype)

    chunk = _kv_chunks(Sk, kv_chunk)
    n_chunks = Sk // chunk
    kc = k.reshape(B, n_chunks, chunk, KV, hd)
    vc = v.reshape(B, n_chunks, chunk, KV, hd)
    pc = k_positions.reshape(B, n_chunks, chunk)

    def step(carry, inputs):
        # named_scope marks this block as VMEM-fused for the roofline memory
        # model: on TPU it runs as the Pallas flash kernel
        # (kernels/attention), whose score/p tensors never touch HBM.
        with jax.named_scope("vmem_fused_attention"):
            m_prev, l_prev, acc_prev = carry
            k_blk, v_blk, p_blk = inputs  # (B, chunk, KV, hd), (B, chunk)
            s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k_blk,
                           preferred_element_type=jnp.float32)  # (B,KV,G,Sq,c)
            if logit_softcap is not None:
                s = jnp.tanh(s / logit_softcap) * logit_softcap
            if causal:
                mask = (q_positions[:, None, None, :, None]
                        >= p_blk[:, None, None, None, :])
            else:
                mask = p_blk[:, None, None, None, :] >= 0
            s = jnp.where(mask, s, NEG_INF)
            m_blk = jnp.max(s, axis=-1)                       # (B,KV,G,Sq)
            m_new = jnp.maximum(m_prev, m_blk)
            # guard fully-masked rows: keep exp finite
            p = jnp.exp(s - m_new[..., None])
            l_corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * l_corr + jnp.sum(p, axis=-1)
            acc_corr = l_corr[..., None]
            acc_blk = jnp.einsum("bkgqc,bckh->bkgqh", p,
                                 v_blk.astype(jnp.float32))
            acc_new = acc_prev * acc_corr + acc_blk
            return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    # scan over chunk axis: move it to front. The step is checkpointed so
    # the backward pass RECOMPUTES per-chunk scores instead of stacking the
    # (Sq × chunk) p-matrices across chunks — the flash-attention schedule
    # expressed in jnp (on TPU the Pallas splash kernel does this in VMEM).
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0))
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable),
        (m0, l0, a0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-37)
    out = jnp.moveaxis(out, 3, 1)                          # (B,Sq,KV,G,hd)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     position: jax.Array, k_positions: jax.Array,
                     scale: float | None = None,
                     logit_softcap: float | None = None) -> jax.Array:
    """Single-step decode: q (B, 1, H, hd) vs cache (B, S, KV, hd); positions
    beyond ``position`` (per batch, (B,)) are masked out. O(S) per step."""
    B, _, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    # vmem_fused: decode attention reads the KV cache ONCE from HBM; scores
    # and the softmax stay on chip (flash-decoding kernel).
    with jax.named_scope("vmem_fused_attention"):
        qg = q.reshape(B, KV, G, hd) * jnp.asarray(scale, q.dtype)
        s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                       preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        mask = k_positions[:, None, None, :] <= position[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
        return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Token-mean CE. logits (B,S,V) fp32-reduced; labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
