"""Mixture-of-Experts FFN with sort-based, capacity-bounded dispatch.

The dispatch is the production formulation (MaxText/Mesh-TF lineage): tokens
are routed top-k, (token, k) pairs are sorted by expert id, each expert takes
at most ``capacity`` tokens (overflow dropped — counted), expert FFNs run as
one grouped einsum over the ``experts`` axis (expert-parallel on the mesh's
``model`` axis), and outputs scatter-add back weighted by router probs.

FLOPs scale with *active* params (tokens × top_k × expert FFN), not total —
which is what makes the MoE roofline rows honest.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from .layers import _activate
from .sharding import constrain


def init_moe_params(key: jax.Array, d_model: int, m: MoEConfig,
                    dtype) -> dict:
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": jax.random.normal(ks[0], (d_model, m.num_experts),
                                    jnp.float32) * scale,
        "wg": jax.random.normal(ks[1], (m.num_experts, d_model, m.d_ff_expert),
                                dtype) * scale,
        "wu": jax.random.normal(ks[2], (m.num_experts, d_model, m.d_ff_expert),
                                dtype) * scale,
        "wd": jax.random.normal(ks[3], (m.num_experts, m.d_ff_expert, d_model),
                                dtype) * (1.0 / math.sqrt(m.d_ff_expert)),
    }
    if m.shared_expert_ff:
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": jax.random.normal(k1, (d_model, m.shared_expert_ff), dtype) * scale,
            "wu": jax.random.normal(k2, (d_model, m.shared_expert_ff), dtype) * scale,
            "wd": jax.random.normal(k3, (m.shared_expert_ff, d_model), dtype)
                  * (1.0 / math.sqrt(m.shared_expert_ff)),
        }
    return p


def capacity_for(num_tokens: int, m: MoEConfig) -> int:
    raw = num_tokens * m.top_k / m.num_experts * m.capacity_factor
    return max(1, int(math.ceil(raw / 8.0)) * 8)   # 8-aligned for TPU tiles


def _dispatch_one_group(xt: jax.Array, router: jax.Array, m: MoEConfig,
                        activation: str, wg, wu, wd, C: int) -> jax.Array:
    """Sort-based capacity-bounded dispatch for ONE token group.
    xt: (Tg, D) -> (Tg, D)."""
    Tg, D = xt.shape
    E, K = m.num_experts, m.top_k

    # -- routing (fp32 for numerics) --------------------------------------
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_ids = jax.lax.top_k(probs, K)          # (Tg, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # -- sort (token, k) pairs by expert ----------------------------------
    flat_ids = gate_ids.reshape(-1)                     # (Tg*K,)
    sort_idx = jnp.argsort(flat_ids)                    # stable
    sorted_ids = flat_ids[sort_idx]
    token_of = sort_idx // K
    w_sorted = gate_w.reshape(-1)[sort_idx]

    counts = jnp.bincount(flat_ids, length=E)           # (E,)
    group_start = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(Tg * K) - group_start[sorted_ids]
    keep = pos_in_expert < C
    slot = sorted_ids * C + jnp.clip(pos_in_expert, 0, C - 1)
    slot = jnp.where(keep, slot, E * C)                 # sentinel row

    # -- dispatch: (E, C, D) expert inputs ---------------------------------
    disp = jnp.zeros((E * C + 1, D), xt.dtype)
    disp = disp.at[slot].set(xt[token_of])              # dropped -> sentinel
    expert_in = disp[: E * C].reshape(E, C, D)

    # -- grouped FFN (expert-parallel over `experts`) ----------------------
    h = _activate(jnp.einsum("ecd,edf->ecf", expert_in, wg), activation)
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
    out_e = jnp.einsum("ecf,efd->ecd", h, wd)

    # -- combine: weighted scatter-add back to token positions -------------
    flat_out = jnp.concatenate(
        [out_e.reshape(E * C, D), jnp.zeros((1, D), out_e.dtype)], axis=0)
    gathered = flat_out[slot] * w_sorted[:, None].astype(out_e.dtype)
    return jnp.zeros((Tg, D), out_e.dtype).at[token_of].add(gathered)


def moe_ffn(x: jax.Array, p: dict, m: MoEConfig, activation: str) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Dispatch is performed per batch-shard *group* (``dispatch_groups()``, =
    number of batch shards on the mesh): the argsort/bincount/scatter that
    route tokens then operate on SPMD-local shapes with zero collectives —
    the global-sort formulation (groups=1, the §Perf baseline) makes XLA
    materialize and sort the full token stream across the mesh. Per-group
    capacity keeps total slots equal, so expert FLOPs are unchanged; only
    the drop pattern differs (local capacity — the standard production
    trade).
    """
    from .sharding import dispatch_groups

    B, S, D = x.shape
    T = B * S
    G = math.gcd(dispatch_groups(), T)
    Tg = T // G
    C = capacity_for(Tg, m)
    xg = x.reshape(G, Tg, D)
    xg = constrain(xg, ("batch", None, None))

    out = jax.vmap(
        lambda xt: _dispatch_one_group(xt, p["router"], m, activation,
                                       p["wg"], p["wu"], p["wd"], C))(xg)
    out = constrain(out, ("batch", None, None))
    out = out.reshape(T, D)

    if m.shared_expert_ff:
        xt = x.reshape(T, D)
        sh = p["shared"]
        g = _activate(jnp.einsum("td,df->tf", xt, sh["wg"]), activation)
        out = out + jnp.einsum("tf,fd->td", g * jnp.einsum(
            "td,df->tf", xt, sh["wu"]), sh["wd"])
    return out.reshape(B, S, D)


def aux_load_balance_loss(x: jax.Array, router: jax.Array, m: MoEConfig) -> jax.Array:
    """Switch-style load-balance auxiliary (mean prob × mean assignment)."""
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = jax.lax.top_k(probs, m.top_k)
    assign = jax.nn.one_hot(ids, m.num_experts, dtype=jnp.float32).sum(-2)
    frac_tokens = assign.mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1))
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)
