"""Training launcher: end-to-end driver wiring every substrate together.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \\
        --steps 200 --reduced --batch-seqs 8 --seq-len 128

Data flows: columnar token shards (engine) → Thallus zero-copy transport
(protocol) → per-column device placement (device_transport) → pjit'd train
step on the host mesh → columnar checkpoints (training.checkpoint). The
``--transport rpc`` flag switches the input pipeline to the serialize-based
baseline — the paper's comparison, selectable in production.

Fault tolerance: resumes from the latest checkpoint (params + optimizer +
data cursor); `--kill-at` simulates a mid-run crash for the restart test.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..core import Fabric, ThallusServer
from ..data import ThallusLoader, make_token_table
from ..engine import Engine
from ..models import make_rules, mesh_context, param_specs
from ..training import (CheckpointManager, OptimizerConfig, TrainConfig,
                        init_train_state, make_train_step)
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-seqs", type=int, default=8)
    ap.add_argument("--num-seqs", type=int, default=4096)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=("none", "dots", "full"))
    ap.add_argument("--transport", default="thallus", choices=("thallus", "rpc"))
    ap.add_argument("--replicas", type=int, default=2,
                    help="data-server replicas (straggler backup)")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="simulate a crash after N steps (restart test)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(learning_rate=args.lr, warmup_steps=20,
                                  decay_steps=max(args.steps, 100)),
        remat=args.remat, microbatches=args.microbatches)

    mesh = make_host_mesh()
    rules = make_rules(cfg, mesh)

    # -- data plane: replicated Thallus servers over columnar token shards
    servers = []
    for r in range(args.replicas):
        eng = Engine()
        eng.register("/data/tokens", make_token_table(
            "tokens", args.num_seqs, args.seq_len, cfg.vocab_size,
            seqs_per_batch=max(args.batch_seqs * 4, 32)))
        servers.append(ThallusServer(eng, Fabric()))
    loader = ThallusLoader(servers, "SELECT tokens FROM tokens",
                           "/data/tokens", seq_len=args.seq_len,
                           batch_seqs=args.batch_seqs,
                           transport=args.transport)

    # -- state: init or resume ------------------------------------------------
    mgr = CheckpointManager(f"{args.ckpt_dir}/{cfg.name}", keep_last=2)
    with mesh, mesh_context(mesh, rules):
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        pspecs = param_specs(cfg, state["params"], mesh)
        state_specs = {"params": pspecs,
                       "opt": {k: pspecs for k in state["opt"]}, "step": P()}
        latest = mgr.latest_step()
        if latest is not None:
            print(f"[resume] restoring step {latest}")
            state, man = mgr.restore(latest, like=state, mesh=mesh,
                                     specs=state_specs)
            loader.load_state_dict(man.cursors)

        step_fn = jax.jit(make_train_step(cfg, tcfg))
        bspec = NamedSharding(mesh, P(tuple(a for a in ("data",)
                                            if a in mesh.axis_names)))
        t0 = time.time()
        tokens_seen = 0
        step = int(state["step"])
        data_iter = iter(loader)
        while step < args.steps:
            try:
                host_batch = next(data_iter)
            except StopIteration:
                loader.load_state_dict({"batch_offset": 0})
                data_iter = iter(loader)
                continue
            batch = {k: jax.device_put(v, bspec) for k, v in host_batch.items()}
            state, metrics = step_fn(state, batch)
            step = int(state["step"])
            tokens_seen += int(metrics["tokens"])
            if step % args.log_every == 0:
                dt = time.time() - t0
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"tok/s {tokens_seen/max(dt,1e-9):,.0f} "
                      f"transport {loader.stats.transport_s*1e3:.1f}ms "
                      f"(backups={loader.stats.backup_requests})", flush=True)
            if args.ckpt_every and step % args.ckpt_every == 0:
                path = mgr.save(step, state, cursors=loader.state_dict())
                print(f"[ckpt] step {step} -> {path}")
            if args.kill_at and step >= args.kill_at:
                print(f"[crash] simulated failure at step {step} — relaunch "
                      "to resume from the latest checkpoint")
                return
        mgr.save(step, state, cursors=loader.state_dict())
        print(f"done: {step} steps, {tokens_seen:,} tokens, "
              f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
