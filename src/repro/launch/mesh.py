"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state. Single pod: (16, 16) = 256 chips, axes (data, model). Multi-pod:
(2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod`` axis composes
with ``data`` for the batch dimension (DP spans pods over DCN; TP stays
intra-pod on ICI).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                                  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                   # pinned jax 0.4.x: Auto is the default
    AxisType = None


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (smoke tests / examples): 1 device -> (1, 1)."""
    n = len(jax.devices())
    model = 1
    for m in (8, 4, 2, 1):
        if n % m == 0:
            model = m
            break
    return _make_mesh((n // model, model), ("data", "model"))
