"""Serving launcher: batched inference with results returned as record
batches over the Thallus transport.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \\
        --reduced --requests 8 --max-new 12

Requests are grouped into aligned cohorts (see serving.batcher), prefilled
once, decoded in lockstep; completions leave as a columnar record batch via
the zero-copy transport (the serving direction of the paper's protocol).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core import Fabric, ThallusTransport
from ..models import decode as decode_fn
from ..models import init_params, make_rules, mesh_context, prefill
from ..serving import Batcher, Request, completions_to_batch
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve example covers LM families; vlm/audio need "
                         "frontend inputs — see examples/")

    mesh = make_host_mesh()
    rules = make_rules(cfg, mesh)
    with mesh, mesh_context(mesh, rules):
        params = init_params(cfg, jax.random.PRNGKey(0))

        def prefill_fn(tokens):
            return prefill(cfg, params, {"tokens": tokens}, remat="none")

        def decode_step(cache, tokens, position):
            return decode_fn(cfg, params, cache, tokens, position)

        batcher = Batcher(jax.jit(prefill_fn), jax.jit(decode_step),
                          batch_size=args.batch_size)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            plen = int(rng.integers(4, args.prompt_len + 1))
            batcher.submit(Request(
                request_id=i,
                prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=args.max_new))
        t0 = time.time()
        completions = batcher.run()
        dt = time.time() - t0

    out_batch = completions_to_batch(completions)
    transport = ThallusTransport(Fabric())
    delivered, stats = transport.send_batch(out_batch)
    total_tokens = sum(len(c.tokens) for c in completions)
    print(f"served {len(completions)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/max(dt,1e-9):.1f} tok/s)")
    print(f"response batch: {delivered.num_rows} rows, "
          f"{delivered.nbytes} bytes, transport {stats.total_s*1e6:.1f}us "
          f"(zero serialize copies: {stats.serialize_s == 0.0})")
    for c in completions[:4]:
        print(f"  req {c.request_id}: {c.tokens}")


if __name__ == "__main__":
    main()
