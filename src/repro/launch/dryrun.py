import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run entry point.

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES  # noqa: E402
from repro.launch.dryrun_lib import format_cell, run_cell, save_artifact  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.training.train_step import TrainConfig  # noqa: E402
from repro.training.optimizer import OptimizerConfig  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower + "
                                 "compile every (arch × shape × mesh) cell")
    ap.add_argument("--arch", choices=ARCH_IDS, action="append")
    ap.add_argument("--shape", choices=tuple(SHAPES), action="append")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2×16×16 = 512-chip mesh")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--remat", default="full", choices=("none", "dots", "full"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-dp", action="store_true",
                    help="int8+EF gradient compression on the DP reduce")
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--layout", default="tp2d", choices=("tp2d", "fsdp"),
                    help="tp2d: 2D data×model; fsdp: pure ZeRO-3 (no TP)")
    ap.add_argument("--baseline-rules", action="store_true",
                    help="paper-baseline sharding: head_dim attention "
                         "fallback + global MoE dispatch (the pre-"
                         "hillclimb configuration)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.all or not args.arch else args.arch
    shapes = list(SHAPES) if args.all or not args.shape else args.shape
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tcfg = TrainConfig(optimizer=OptimizerConfig(), remat=args.remat,
                       microbatches=args.microbatches,
                       compress_dp_grads=args.compress_dp,
                       param_dtype="bfloat16")

    options = {"layout": args.layout}
    if args.baseline_rules:
        options.update(attn_fallback="head_dim", moe_local_dispatch=False)

    failures = 0
    for arch in archs:
        for shape in shapes:
            art = run_cell(arch, shape, mesh, tcfg=tcfg,
                           collect_hlo=args.print_hlo, options=options)
            path = save_artifact(art, args.out)
            print(format_cell(art), flush=True)
            if art["status"] == "ok":
                mem = art["memory"]
                print(f"    memory_analysis: args={mem['argument_bytes']/2**30:.2f}GiB "
                      f"out={mem['output_bytes']/2**30:.2f}GiB "
                      f"temp={mem['temp_bytes']/2**30:.2f}GiB   "
                      f"cost: flops/dev={art['cost'].get('flops',0):.3e} "
                      f"bytes/dev={art['cost'].get('bytes accessed',0):.3e}")
                print(f"    collectives: "
                      f"{json.dumps(art['collectives']['counts'])} "
                      f"wire={art['collectives']['total_wire_bytes']/2**20:.1f}MiB/dev "
                      f"-> {path}")
            if args.print_hlo and "hlo" in art:
                print(art["hlo"][:20000])
            failures += art["status"] == "error"
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
