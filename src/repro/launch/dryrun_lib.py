"""Dry-run core: lower + compile every (arch × shape × mesh) cell, extract
memory / cost / collective analysis, emit JSON artifacts.

No XLA_FLAGS side effects here — ``dryrun.py`` (the CLI) sets the 512-device
host platform before importing anything; tests and benchmarks import *this*
module safely under a 1-device runtime (they pass small meshes).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ArchConfig, ShapeConfig, get_config, shape_applicable
from ..models import (batch_pspecs, cache_pspecs, cache_spec, decode,
                      make_rules, mesh_context, param_shapes, param_specs,
                      prefill)
from ..models.model import Params
from ..training.train_step import TrainConfig, make_train_step, train_state_shapes
from ..utils.hlo import collective_stats
from ..utils.hlo_cost import FUSED_ATTENTION_FNS, analyze as hlo_analyze
from ..utils.roofline import Roofline, model_flops


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch: dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.family == "vlm":
            pn = cfg.vlm.num_patches
            batch["tokens"] = jax.ShapeDtypeStruct((B, S - pn), i32)
            batch["labels"] = jax.ShapeDtypeStruct((B, S - pn), i32)
            batch["patch_embeds"] = jax.ShapeDtypeStruct((B, pn, cfg.d_model), dtype)
        elif cfg.family == "audio":
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.encoder_seq, cfg.d_model), dtype)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.family == "vlm":
            pn = cfg.vlm.num_patches
            batch["tokens"] = jax.ShapeDtypeStruct((B, S - pn), i32)
            batch["patch_embeds"] = jax.ShapeDtypeStruct((B, pn, cfg.d_model), dtype)
        elif cfg.family == "audio":
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.encoder_seq, cfg.d_model), dtype)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "position": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               tcfg: TrainConfig, options: dict | None = None):
    """Returns (fn, args_shapes, in_shardings, out_shardings, donate).

    Production memory posture: the train state / decode cache argument is
    DONATED (in-place update, no double residency), and outputs carry
    explicit shardings so prefill caches land sharded instead of wherever
    propagation leaves them.
    """
    dtype = jnp.dtype(tcfg.param_dtype)
    pshapes = param_shapes(cfg, dtype)
    pspecs = param_specs(cfg, pshapes, mesh, options)

    if shape.kind == "train":
        state_shapes = train_state_shapes(cfg, tcfg)
        state_specs: dict[str, Any] = {
            "params": pspecs,
            "opt": {k: pspecs for k in state_shapes["opt"]},
            "step": P(),
        }
        if "ef" in state_shapes:
            state_specs["ef"] = pspecs
        batch_shapes = input_specs(cfg, shape, dtype)
        bspecs = batch_pspecs(cfg, batch_shapes, mesh, options)
        fn = make_train_step(cfg, tcfg)
        metric_specs = {"loss": P(), "grad_norm": P(), "lr": P(), "tokens": P()}
        return (fn, (state_shapes, batch_shapes),
                (_named(mesh, state_specs), _named(mesh, bspecs)),
                (_named(mesh, state_specs), _named(mesh, metric_specs)),
                (0,))

    if shape.kind == "prefill":
        batch_shapes = input_specs(cfg, shape, dtype)
        bspecs = batch_pspecs(cfg, batch_shapes, mesh, options)
        cshape = cache_spec(cfg, shape.global_batch, shape.seq_len, dtype)
        cspecs = cache_pspecs(cfg, cshape, mesh, options)
        rules = make_rules(cfg, mesh, options)
        from ..models.sharding import spec_of
        logit_spec = spec_of(("batch", None, "vocab"), rules,
                             shape=(shape.global_batch, 1, cfg.padded_vocab),
                             mesh=mesh)

        def fn(params, batch):
            return prefill(cfg, params, batch, remat=tcfg.remat)

        return (fn, (pshapes, batch_shapes),
                (_named(mesh, pspecs), _named(mesh, bspecs)),
                (NamedSharding(mesh, logit_spec), _named(mesh, cspecs)),
                ())

    # decode
    cshape = cache_spec(cfg, shape.global_batch, shape.seq_len, dtype)
    cspecs = cache_pspecs(cfg, cshape, mesh, options)
    batch_shapes = input_specs(cfg, shape, dtype)
    bspecs = batch_pspecs(cfg, batch_shapes, mesh, options)
    rules = make_rules(cfg, mesh, options)
    from ..models.sharding import spec_of
    logit_spec = spec_of(("batch", None, "vocab"), rules,
                         shape=(shape.global_batch, 1, cfg.padded_vocab),
                         mesh=mesh)

    def fn(params, cache, tokens, position):
        return decode(cfg, params, cache, tokens, position)

    return (fn, (pshapes, cshape, batch_shapes["tokens"],
                 batch_shapes["position"]),
            (_named(mesh, pspecs), _named(mesh, cspecs),
             NamedSharding(mesh, bspecs["tokens"]),
             NamedSharding(mesh, bspecs["position"])),
            (NamedSharding(mesh, logit_spec), _named(mesh, cspecs)),
            (1,))


HBM_BYTES_PER_DEVICE = 16 * 2**30   # v5e-class


def run_cell(arch: str, shape_name: str, mesh: Mesh, *,
             tcfg: TrainConfig | None = None,
             collect_hlo: bool = False,
             auto_fit: bool = True,
             options: dict | None = None) -> dict:
    """Lower + compile one cell; return the artifact dict.

    ``auto_fit``: if a *train* cell's per-device peak exceeds HBM, retry
    with more gradient-accumulation microbatches (4, then 16) — the same
    fit loop the real launcher would run.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    num_devices = mesh.size
    art: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "devices": num_devices,
        "kind": shape.kind, "status": "skipped", "skip_reason": why,
    }
    if not ok:
        return art
    tcfg = tcfg or TrainConfig(param_dtype="bfloat16", remat="full")
    options = dict(options or {})
    options.setdefault("global_batch", shape.global_batch)
    rules = make_rules(cfg, mesh, options)
    art["rules"] = {k: (list(v) if isinstance(v, tuple) else v)
                    for k, v in rules.items()}
    t0 = time.perf_counter()
    try:
        mb_ladder = [tcfg.microbatches]
        if auto_fit and shape.kind == "train":
            mb_ladder += [m for m in (4, 16) if m > tcfg.microbatches
                          and shape.global_batch % m == 0]
        compiled = None
        for mb in mb_ladder:
            tcfg_i = dataclasses.replace(tcfg, microbatches=mb)
            fn, args, in_shardings, out_shardings, donate = build_cell(
                cfg, shape, mesh, tcfg_i, options)
            with mesh, mesh_context(mesh, rules):
                lowered = jax.jit(fn, in_shardings=in_shardings,
                                  out_shardings=out_shardings,
                                  donate_argnums=donate).lower(*args)
                t1 = time.perf_counter()
                compiled = lowered.compile()
                t2 = time.perf_counter()
            mem_try = compiled.memory_analysis()
            peak = (mem_try.temp_size_in_bytes
                    + max(mem_try.argument_size_in_bytes,
                          mem_try.output_size_in_bytes))
            art["microbatches"] = mb
            if peak <= HBM_BYTES_PER_DEVICE or mb == mb_ladder[-1]:
                break
            art.setdefault("autofit_attempts", []).append(
                {"microbatches": mb, "peak_bytes": int(peak)})
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        donated = bool(donate)
        _peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 - (min(mem.output_size_in_bytes, mem.temp_size_in_bytes)
                    if donated else 0)
                 + (0 if donated else mem.output_size_in_bytes))
        colls = collective_stats(hlo, num_devices)   # static (no-loop) view
        loop_cost = hlo_analyze(hlo, num_devices)    # trip-count-aware
        # second accounting: attention/SSD interiors as fused Pallas kernels
        # (VMEM-resident scores) — the TPU-native memory model
        fused_cost = hlo_analyze(hlo, num_devices,
                                 fused_functions=FUSED_ATTENTION_FNS)

        n_params = cfg.num_params()
        n_active = cfg.num_params(active_only=True)
        mf = model_flops(n_active, shape.tokens_per_step, shape.kind)
        roof = Roofline(
            flops_per_device=loop_cost.flops,
            bytes_per_device=loop_cost.bytes,
            collective_bytes_per_device=loop_cost.collective_wire_bytes,
            model_flops_per_device=mf / num_devices,
        )
        roof_fused = Roofline(
            flops_per_device=fused_cost.flops,
            bytes_per_device=fused_cost.bytes,
            collective_bytes_per_device=fused_cost.collective_wire_bytes,
            model_flops_per_device=mf / num_devices,
        )
        art.update(
            status="ok",
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
                "donated_args": donated,
                # XLA:CPU ignores donation, so its `temp` contains a fresh
                # copy of the (donated) state/cache that TPU would alias in
                # place. TPU-peak model: args + temp, minus the output-sized
                # copy when args are donated. Raw numbers stay above.
                "peak_bytes_per_device": int(_peak),
                "fits_hbm": bool(_peak <= HBM_BYTES_PER_DEVICE),
            },
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
            loop_cost={
                "flops": loop_cost.flops,
                "transcendentals": loop_cost.transcendentals,
                "bytes": loop_cost.bytes,
                "collective_wire_bytes": loop_cost.collective_wire_bytes,
                "collective_counts": loop_cost.collective_counts,
                "collective_bytes_by_op": loop_cost.collective_bytes_by_op,
            },
            collectives={
                "counts": colls.counts,
                "wire_bytes": colls.wire_bytes,
                "total_wire_bytes": colls.total_wire_bytes,
            },
            params=n_params, active_params=n_active,
            tokens_per_step=shape.tokens_per_step,
            roofline=roof.to_dict(),
            roofline_fused=roof_fused.to_dict(),
        )
        if collect_hlo:
            art["hlo"] = hlo
    except Exception as e:  # noqa: BLE001 — cell failures are data
        art.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return art


def save_artifact(art: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "x".join(str(v) for v in art["mesh"].values())
    path = os.path.join(out_dir, f"{art['arch']}_{art['shape']}_{mesh_tag}.json")
    art = {k: v for k, v in art.items() if k != "hlo"}
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return path


def format_cell(art: dict) -> str:
    if art["status"] == "skipped":
        return f"{art['arch']:24s} {art['shape']:12s} SKIP ({art['skip_reason']})"
    if art["status"] == "error":
        return f"{art['arch']:24s} {art['shape']:12s} ERROR {art['error'][:90]}"
    r = art["roofline"]
    rf = art.get("roofline_fused", r)
    m = art["memory"]
    return (f"{art['arch']:24s} {art['shape']:12s} ok "
            f"compile={art['compile_s']:6.1f}s "
            f"mem/dev={m['peak_bytes_per_device']/2**30:6.2f}GiB "
            f"C={r['compute_s']*1e3:8.2f}ms M={r['memory_s']*1e3:8.2f}ms "
            f"(fused {rf['memory_s']*1e3:8.2f}ms) "
            f"X={r['collective_s']*1e3:8.2f}ms -> {rf['bottleneck']:10s} "
            f"useful={r['useful_flops_ratio']:5.2f} "
            f"mfu≤{rf['mfu_bound']:.2f}")
