from .tokens import TOKEN_SCHEMA, batch_to_tokens, make_token_table, shift_labels  # noqa: F401
from .loader import LoaderStats, ThallusLoader  # noqa: F401
