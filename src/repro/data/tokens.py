"""Columnar token datasets: LM training data stored Arrow-style.

A token shard is a table with columns (seq_id int64, tokens int32) where
``tokens`` holds ``rows × seq_len`` values flattened row-major — the layout
a tokenizer pipeline would emit into Arrow. Batches reshape *by view* (the
Thallus path keeps them zero-copy end to end).
"""
from __future__ import annotations

import numpy as np

from ..core.recordbatch import RecordBatch, batch_from_arrays
from ..core.schema import schema as make_schema
from ..engine.table import Table

TOKEN_SCHEMA = make_schema(("seq_id", "int64"), ("tokens", "int32"))


def make_token_table(name: str, num_seqs: int, seq_len: int,
                     vocab_size: int, seqs_per_batch: int = 64,
                     seed: int = 0) -> Table:
    """Synthetic tokenized corpus (markov-ish for non-uniform stats)."""
    rng = np.random.default_rng(seed)
    table = Table(name, TOKEN_SCHEMA)
    done = 0
    while done < num_seqs:
        n = min(seqs_per_batch, num_seqs - done)
        toks = rng.integers(0, vocab_size, (n, seq_len), dtype=np.int32)
        # inject local structure so loss curves move in the examples
        toks[:, 1::2] = (toks[:, ::2] * 31 + 7) % vocab_size
        seq_ids = (np.arange(n, dtype=np.int64) + done)
        batch = batch_from_arrays(
            TOKEN_SCHEMA, [np.repeat(seq_ids, seq_len),
                           toks.reshape(-1)])
        table.append(batch)
        done += n
    return table


def batch_to_tokens(batch: RecordBatch, seq_len: int) -> np.ndarray:
    """(rows*seq_len,) int32 column -> (rows, seq_len) view (zero-copy)."""
    col = batch.column("tokens").values
    if col.size % seq_len:
        raise ValueError(f"column size {col.size} not divisible by {seq_len}")
    return col.reshape(-1, seq_len)


def shift_labels(tokens: np.ndarray, pad_id: int = -1) -> np.ndarray:
    """Next-token labels: labels[t] = tokens[t+1]; last position masked."""
    labels = np.concatenate(
        [tokens[:, 1:], np.full((tokens.shape[0], 1), pad_id, tokens.dtype)],
        axis=1)
    return labels
