"""ThallusLoader: the paper's protocol as a training input pipeline.

Server side: token shards behind the query engine. Client side: each
training job ``init_scan``s its shard query, streams record batches via the
zero-copy transport, reshapes token columns *by view*, and lands per-column
device arrays on the mesh (`batch_to_device` — the scatter-gather path).

Cluster-scale behaviours implemented here:

* **replicated servers + backup requests** (straggler mitigation): every
  batch is requested from the primary; if the primary's simulated response
  time exceeds ``straggler_deadline_s`` (or it raises), the loader pulls the
  batch from the next replica — first-ready wins, MapReduce-style.
* **resumable cursors**: `state_dict()`/`load_state_dict()` round-trip the
  batch offset through the checkpoint manifest; restart fast-forwards via
  ``init_scan(start_batch=...)``.
* **transport choice**: "thallus" (zero-copy) or "rpc" (serialize) — the
  benchmark axis of the paper, selectable end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from ..core.protocol import RpcClient, ThallusClient, ThallusServer
from ..core.recordbatch import RecordBatch
from .tokens import batch_to_tokens, shift_labels


@dataclasses.dataclass
class LoaderStats:
    batches: int = 0
    backup_requests: int = 0
    transport_s: float = 0.0


class ThallusLoader:
    """Streams (tokens, labels) numpy batches; device placement is the
    trainer's job (it owns the mesh)."""

    def __init__(self, servers: list[ThallusServer], sql: str, dataset: str,
                 seq_len: int, batch_seqs: int, transport: str = "thallus",
                 straggler_deadline_s: float = 0.5, start_batch: int = 0):
        if not servers:
            raise ValueError("need at least one server")
        self.servers = servers
        self.sql = sql
        self.dataset = dataset
        self.seq_len = seq_len
        self.batch_seqs = batch_seqs
        self.transport = transport
        self.deadline = straggler_deadline_s
        self.stats = LoaderStats()
        self._offset = start_batch
        self._buffer: list[np.ndarray] = []    # leftover sequences

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"batch_offset": self._offset}

    def load_state_dict(self, d: dict[str, int]) -> None:
        self._offset = int(d["batch_offset"])
        self._buffer.clear()

    # -- streaming ----------------------------------------------------------
    def _pull_batches(self) -> Iterator[RecordBatch]:
        """Stream record batches from the first-ready replica per batch."""
        clients = []
        for server in self.servers:
            cls = ThallusClient if self.transport == "thallus" else RpcClient
            clients.append(cls(server))
        primary = clients[0]
        batches = primary.run_query(self.sql, self.dataset,
                                    **({"start_batch": self._offset}
                                       if self.transport == "thallus" else {}))
        for i, b in enumerate(batches):
            stats = primary.stats[i]
            if stats.total_s > self.deadline and len(clients) > 1:
                # straggler: issue backup request to replica for this batch
                backup = clients[1]
                rb = backup.run_query(self.sql, self.dataset,
                                      **({"start_batch": self._offset + i}
                                         if self.transport == "thallus" else {}))
                self.stats.backup_requests += 1
                b = rb[0] if rb else b
            self.stats.transport_s += stats.total_s
            self.stats.batches += 1
            self._offset += 1
            yield b

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        for rb in self._pull_batches():
            seqs = batch_to_tokens(rb, self.seq_len)
            self._buffer.extend(seqs)
            while len(self._buffer) >= self.batch_seqs:
                chunk = np.stack(self._buffer[: self.batch_seqs])
                del self._buffer[: self.batch_seqs]
                yield {"tokens": chunk, "labels": shift_labels(chunk)}
