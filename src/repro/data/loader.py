"""ThallusLoader: the paper's protocol as a training input pipeline.

Server side: token shards behind the query engine. Client side: each
training job ``init_scan``s its shard query, streams record batches via the
zero-copy transport, reshapes token columns *by view* (``batch_to_device``
being the trainer's job), and feeds (tokens, labels) numpy batches.

Three transports, one knob:

* ``"thallus"`` / ``"rpc"`` — the paper's single-stream scan (zero-copy vs
  serialize), with **backup requests**: if the primary's simulated response
  time exceeds ``straggler_deadline_s``, the batch is re-pulled from the
  next replica, first-ready wins.
* ``"cluster"`` — the :mod:`repro.cluster` dataplane: the query is planned
  into per-replica batch-range partitions (``placement="replica"``, or
  ``"shard"`` if the servers hold disjoint shards), pulled over N concurrent
  leases through a registered buffer pool. This subsumes the backup-request
  hack — a slow or failed stream is resumed individually via
  ``init_scan(start_batch=…)`` instead of re-running the whole query.
* ``"gateway"`` — the loader submits one logical
  ``qos.ScanRequest`` per epoch through a ``qos.ScanGateway`` (pass
  ``gateway=``). The scan then rides whatever adaptive scheduling the
  gateway carries: identical concurrent queries coalesce onto a shared
  ticket (``LoaderStats.shared_scans`` counts multicast grants that cost no
  extra server work), a batch-class scan may be preempted at lease
  boundaries by interactive traffic (``LoaderStats.preemptions``), and
  stragglers are work-stolen. Resume uses the request's ``start_batch``
  (global scan order — the gateway reassembles before the loader sees
  batches, so per-stream offsets are unnecessary).

Resumable cursors in every mode: ``state_dict()``/``load_state_dict()``
round-trip the cursor through the checkpoint manifest. Cluster mode tracks
*per-stream* offsets (the merged order is only defined per stream).

Cluster mode is admission-aware: pass a ``qos.AdmissionController`` (plus a
``client_id``) and every stream lease is granted through it. A
``qos.ShardedAdmission`` works identically — the loader's coordinator names
its servers ``s0..sN-1`` and routes every lease to the endpoint server's
quota shard, so build the controller over the same ids
(``ShardedAdmission(cfg, [f"s{i}" for i in range(n)])``). A denied grant
— stream quota hit, registered-memory budget exhausted — surfaces to the
caller as :class:`repro.qos.Backpressure` with a ``retry_after_s`` hint and
bumps ``LoaderStats.backpressures``; the loader's cursor state is
unchanged, so the caller simply waits and re-iterates (or narrows
``num_streams`` under its quota). Gateway mode never sees that exception —
the gateway queues or sheds instead — so a scan shed or failed while
queued yields an **empty epoch** with ``backpressures`` bumped and the
cursor unchanged: check it to distinguish "retry later" from "dataset
exhausted".
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from ..cluster import BufferPool, ClusterCoordinator, MultiStreamPuller
from ..core.protocol import RpcClient, ThallusClient, ThallusServer
from ..core.recordbatch import RecordBatch
from .tokens import batch_to_tokens, shift_labels


@dataclasses.dataclass
class LoaderStats:
    batches: int = 0
    backup_requests: int = 0
    stream_resumes: int = 0
    transport_s: float = 0.0
    shared_scans: int = 0        # gateway scans served by ticket multicast
    preemptions: int = 0         # times a gateway scan parked mid-flight
    backpressures: int = 0       # admission denials surfaced to the caller


class ThallusLoader:
    """Streams (tokens, labels) numpy batches; device placement is the
    trainer's job (it owns the mesh)."""

    def __init__(self, servers: list[ThallusServer], sql: str, dataset: str,
                 seq_len: int, batch_seqs: int, transport: str = "thallus",
                 straggler_deadline_s: float = 0.5, start_batch: int = 0,
                 num_streams: int | None = None, use_pool: bool = True,
                 placement: str = "replica", admission=None,
                 client_id: str = "loader", gateway=None,
                 klass: str = "batch"):
        if transport == "gateway":
            if gateway is None:
                raise ValueError("transport='gateway' needs a gateway=")
        elif not servers:
            raise ValueError("need at least one server")
        if transport not in ("thallus", "rpc", "cluster", "gateway"):
            raise ValueError(f"unknown transport {transport!r}")
        self.servers = servers
        self.sql = sql
        self.dataset = dataset
        self.seq_len = seq_len
        self.batch_seqs = batch_seqs
        self.transport = transport
        self.deadline = straggler_deadline_s
        self.num_streams = num_streams
        self.use_pool = use_pool
        self.placement = placement
        self.admission = admission
        self.client_id = client_id
        self.gateway = gateway
        self.klass = klass
        self.stats = LoaderStats()
        self._offset = start_batch
        self._stream_offsets: list[int] = []
        self._buffer: list[np.ndarray] = []    # leftover sequences

    # -- telemetry ----------------------------------------------------------
    def metrics(self) -> "MetricsRegistry":
        """The loader-level telemetry roll-up: its own ``loader.*``
        counters plus everything the gateway below it saw (``qos.*``,
        ``sched.*``, ``cluster.*``, ``pool.*``) when one is attached —
        one ``snapshot()`` for the whole data path."""
        from ..obs.registry import (MetricsRegistry, record_gateway,
                                    record_loader)
        reg = MetricsRegistry()
        record_loader(reg, self.stats)
        if self.gateway is not None:
            record_gateway(reg, self.gateway)
        monitor = self._health_monitor()
        if monitor is not None:
            from ..obs.registry import record_health
            record_health(reg, monitor)
        return reg

    def health(self) -> dict:
        """Per-server health verdicts from the cluster's
        ``obs.HealthMonitor`` when one is attached to the gateway's
        coordinator (``{server_id: "healthy" | "degraded" | "suspect" |
        "quarantined"}``); ``{}`` when no monitor watches this data path."""
        monitor = self._health_monitor()
        if monitor is None:
            return {}
        return monitor.states()

    def _health_monitor(self):
        coordinator = getattr(self.gateway, "coordinator", None)
        return getattr(coordinator, "health", None)

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict:
        return {"batch_offset": self._offset,
                "stream_offsets": list(self._stream_offsets)}

    def load_state_dict(self, d: dict) -> None:
        self._offset = int(d["batch_offset"])
        self._stream_offsets = [int(v) for v in d.get("stream_offsets", [])]
        self._buffer.clear()

    # -- streaming ----------------------------------------------------------
    def _pull_batches(self) -> Iterator[RecordBatch]:
        if self.transport == "cluster":
            yield from self._pull_cluster()
        elif self.transport == "gateway":
            yield from self._pull_gateway()
        else:
            yield from self._pull_single_stream()

    def _pull_single_stream(self) -> Iterator[RecordBatch]:
        """Stream record batches from the first-ready replica per batch."""
        cls = ThallusClient if self.transport == "thallus" else RpcClient
        primary = cls(self.servers[0])
        batches = primary.run_query(self.sql, self.dataset,
                                    start_batch=self._offset)
        for i, b in enumerate(batches):
            stats = primary.stats[i]
            if stats.total_s > self.deadline and len(self.servers) > 1:
                # straggler: issue backup request to a replica for exactly
                # this batch. self._offset is its global index (advanced
                # once per earlier batch); the client is fresh and the pull
                # bounded, so rb == [that one batch].
                backup = cls(self.servers[1])
                rb = backup.run_query(self.sql, self.dataset,
                                      start_batch=self._offset,
                                      max_batches=1)
                self.stats.backup_requests += 1
                b = rb[0] if rb else b
            self.stats.transport_s += stats.total_s
            self.stats.batches += 1
            self._offset += 1
            yield b

    def _pull_gateway(self) -> Iterator[RecordBatch]:
        """One logical scan through the qos gateway, resumed by global
        offset: the request's ``start_batch`` IS the loader cursor (the
        gateway pushes it down into replica plans, or trims the reassembled
        head for shard plans), so checkpoint state stays a single integer.
        Surfaces the adaptive-scheduler outcomes: ``shared_scans`` when the
        result arrived by shared-ticket multicast, ``preemptions`` when the
        scan was parked for interactive traffic mid-flight."""
        from ..qos import ScanRequest   # data -> qos only on this path
        request = self.gateway.submit(ScanRequest(
            self.client_id, self.klass, self.sql, self.dataset,
            num_streams=self.num_streams, start_batch=self._offset))
        if request is None:             # shed at submit (deadline policy)
            self.stats.backpressures += 1
            return
        self.gateway.run()
        result = self.gateway.result(request.request_id)
        if result is None:
            # shed or failed while queued: the gateway converts admission
            # denials to sheds instead of raising, so the empty epoch is
            # flagged here — callers distinguish it from dataset
            # exhaustion via stats.backpressures and retry
            self.stats.backpressures += 1
            return
        self.stats.shared_scans += int(result.shared)
        self.stats.preemptions += result.preemptions
        self.stats.stream_resumes += result.cluster.resumes
        self.stats.transport_s += result.service_s
        try:
            for batch in result.batches:
                self._offset += 1
                self.stats.batches += 1
                yield batch
        finally:
            # the loader re-submits every epoch; leaving each epoch's fully
            # materialized result in the gateway map would grow unbounded
            self.gateway.results.pop(request.request_id, None)

    def _pull_cluster(self) -> Iterator[RecordBatch]:
        """Partitioned multi-stream pull with per-stream resume offsets.

        Resume semantics: when the checkpoint carries ``stream_offsets``
        (written by a cluster-mode run), each stream fast-forwards
        server-side via ``init_scan(start_batch=…)`` — no wasted transport.
        A bare global offset (the ``start_batch`` constructor arg, or a
        checkpoint from a single-stream run) cannot be mapped onto streams
        exactly, so the first ``offset`` batches are pulled and discarded —
        correct under any schedule, at the cost of re-transporting them.

        With the pool on, a yielded batch's buffers are recycled once the
        next batch is requested, so ``__iter__`` copies the token block out
        (the np.stack that builds training chunks copies regardless)."""
        coordinator = ClusterCoordinator(admission=self.admission)
        for i, server in enumerate(self.servers):
            coordinator.add_server(f"s{i}", server)
        plan = coordinator.plan(self.sql, self.dataset,
                                num_streams=self.num_streams,
                                placement=self.placement)
        # fast-forward each stream past what previous runs already delivered
        if self._stream_offsets and \
                len(self._stream_offsets) != len(plan.endpoints):
            raise ValueError(
                f"checkpoint has {len(self._stream_offsets)} stream offsets "
                f"but the plan has {len(plan.endpoints)} endpoints")
        offsets = self._stream_offsets or [0] * len(plan.endpoints)
        endpoints = tuple(
            dataclasses.replace(
                ep, start_batch=ep.start_batch + off,
                max_batches=(None if ep.max_batches is None
                             else ep.max_batches - off))
            for ep, off in zip(plan.endpoints, offsets))
        plan = dataclasses.replace(plan, endpoints=endpoints)
        pool = BufferPool(self.servers[0].fabric) if self.use_pool else None
        # Backpressure from an admission controller propagates from here:
        # no lease opened yet counts against the cursor, so the caller can
        # retry after `retry_after_s` with state intact
        try:
            puller = MultiStreamPuller(coordinator, plan, pool=pool,
                                       schedule="round_robin",
                                       client_id=self.client_id)
        except Exception as exc:
            # duck-typed qos.Backpressure (data -> qos stays import-free)
            if hasattr(exc, "retry_after_s"):
                self.stats.backpressures += 1
            raise
        self._stream_offsets = offsets
        skip = self._offset - sum(offsets)   # global offset not yet mapped
        if skip < 0:
            raise ValueError(
                f"inconsistent checkpoint: batch_offset={self._offset} < "
                f"sum(stream_offsets)={sum(offsets)}")
        try:
            for idx, batch in puller.batches():
                self._stream_offsets[idx] += 1
                if skip > 0:    # already consumed before this incarnation
                    skip -= 1
                    continue
                self._offset += 1
                self.stats.batches += 1
                yield batch
        finally:
            # a consumer that stops early (checkpoint-and-exit) still pulled
            # batches — account whatever transport accrued, drained or not
            cluster = puller.stats()
            self.stats.stream_resumes += cluster.resumes
            self.stats.transport_s += cluster.critical_path_s

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        copy_out = self.transport == "cluster" and self.use_pool
        for rb in self._pull_batches():
            seqs = batch_to_tokens(rb, self.seq_len)
            if copy_out:
                seqs = seqs.copy()     # pooled buffers are about to recycle
            self._buffer.extend(seqs)
            while len(self._buffer) >= self.batch_seqs:
                chunk = np.stack(self._buffer[: self.batch_seqs])
                del self._buffer[: self.batch_seqs]
                yield {"tokens": chunk, "labels": shift_labels(chunk)}
