"""Quickstart: the Thallus protocol end to end.

Builds a columnar dataset, runs a SQL query on the server, streams the
results to a client over BOTH transports, prints the paper's headline
comparison (zero-copy vs serialize), scales the same scan out as a
partitioned multi-stream pull through the ``repro.cluster`` dataplane,
routes contending clients through the ``repro.qos`` gateway so a heavy
batch scan cannot starve interactive traffic, turns on the ``repro.sched``
adaptive scheduler — a 4×-slow replica is rescued by work stealing,
identical queued queries coalesce onto a shared ticket, and an interactive
arrival preempts a batch scan at a lease boundary — and finally shards the
admission budget per server (``qos.ShardedAdmission``): a saturated shard
borrows slack from its least-loaded peer, the modeled-time reconciler
levels capacity and lease tokens back out, and a batch client closing its
streams mid-scan lets the gateway re-plan an interactive fan-out onto the
freed lanes. Finally the ``repro.obs`` stress driver runs a seeded client
population mix (interactive / batch / scan storm) through one gateway and
prints per-population fairness telemetry.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.cluster import (BufferPool, ClusterCoordinator, MultiStreamPuller,
                           cluster_scan)
from repro.core import (Fabric, FabricConfig, RpcClient, ThallusClient,
                        ThallusServer)
from repro.engine import Engine, make_numeric_table
from repro.obs import (ClientPopulation, FlightRecorder, StressDriver,
                       population_classes)
from repro.qos import (AdmissionConfig, AdmissionController, Backpressure,
                       ClientClass, ScanGateway, ScanRequest,
                       ShardedAdmission)
from repro.sched import AdaptiveScheduler, StealConfig
from repro.utils.report import admission_table, sched_table, workload_table


def main() -> None:
    # -- server: a DuckDB-style engine over columnar shards -----------------
    engine = Engine()
    engine.register("/data/events",
                    make_numeric_table("events", 1 << 18, 8,
                                       batch_rows=1 << 15))
    server = ThallusServer(engine, Fabric())

    sql = "SELECT c0, c1, c2, c3 FROM events WHERE c0 > 0.5"

    # -- the paper's protocol: init_scan -> iterate(do_rdma) -> finalize ----
    thallus = ThallusClient(server)
    batches = thallus.run_query(sql, "/data/events")
    rows = sum(b.num_rows for b in batches)
    print(f"thallus: {len(batches)} batches, {rows} rows")
    print(f"  transport {thallus.transport_seconds()*1e3:.2f} ms "
          f"(serialize copies: 0 — buffers were exposed in place)")

    # -- the baseline: serialize into one buffer, ship over RPC -------------
    rpc = RpcClient(server)
    rpc.run_query(sql, "/data/events")
    ser = sum(s.serialize_s for s in rpc.stats)
    print(f"rpc:     transport {rpc.transport_seconds()*1e3:.2f} ms "
          f"({ser/rpc.transport_seconds():.0%} of it serializing)")
    print(f"speedup: {rpc.transport_seconds()/thallus.transport_seconds():.2f}x "
          "(paper: up to 5.5x, shrinking with result size)")

    # -- results agree bit-for-bit ------------------------------------------
    a = np.concatenate([b.column("c1").values for b in thallus.batches])
    b = np.concatenate([b.column("c1").values for b in rpc.batches])
    np.testing.assert_array_equal(a, b)
    print("transports agree bit-for-bit")

    # -- cluster dataplane: the same scan, partitioned across 4 shards ------
    coordinator = ClusterCoordinator()
    for i in range(4):
        coordinator.add_server(f"s{i}", ThallusServer(Engine(), Fabric()))
    coordinator.place_shards("/data/events",
                             engine.catalog.get("/data/events"))
    pool = BufferPool(coordinator.server("s0").fabric)
    total = {"rows": 0, "sum": 0.0}

    def sink(stream_idx, batch):  # pooled buffers recycle after this returns
        total["rows"] += batch.num_rows
        total["sum"] += float(batch.column("c1").values.sum())

    stats = cluster_scan(coordinator, sql, "/data/events",
                         pool=pool, sink=sink)
    print(f"cluster: {stats.batches} batches over "
          f"{len(stats.streams)} streams, {total['rows']} rows")
    print(f"  critical path {stats.critical_path_s*1e3:.2f} ms "
          f"(serial work {stats.sum_total_s*1e3:.2f} ms), "
          f"pool hit rate {pool.stats.hit_rate:.0%}, modeled registration "
          f"{stats.modeled_register_s*1e6:.1f} us")
    np.testing.assert_allclose(total["sum"], float(a.sum()), rtol=1e-9)
    print("partitioned scan agrees with the single-stream result")

    # -- qos gateway: heavy batch scans vs interactive lookups --------------
    admission = AdmissionController(AdmissionConfig(
        max_streams_per_client=2, lease_rate_per_s=1e4, lease_burst=8))
    gateway = ScanGateway(
        coordinator,
        classes=[ClientClass("interactive", 4.0), ClientClass("batch", 1.0)],
        admission=admission)
    for _ in range(3):   # a heavy client floods the queue first...
        gateway.submit(ScanRequest(
            "trainer", "batch",
            "SELECT " + ", ".join(f"c{i}" for i in range(8)) + " FROM events",
            "/data/events", cost_hint=8.0))
    ui = gateway.submit(ScanRequest(            # ...then a lookup arrives
        "dashboard", "interactive", sql, "/data/events", cost_hint=1.0))
    gateway.run()
    result = gateway.result(ui.request_id)
    rows = sum(b.num_rows for b in result.batches)
    qos = gateway.stats
    print(f"qos: interactive request reassembled {len(result.batches)} "
          f"batches ({rows} rows) in scan order")
    print(f"  p50 grant latency: interactive "
          f"{qos.klass('interactive').p50_grant_latency_s*1e3:.2f} ms vs "
          f"batch {qos.klass('batch').p50_grant_latency_s*1e3:.2f} ms "
          f"(weighted-fair: the lookup jumped the heavy queue)")
    got = np.concatenate([b.column("c1").values for b in result.batches])
    np.testing.assert_array_equal(np.sort(got), np.sort(a))
    print("gateway scatter-gather agrees with the single-stream result")

    # -- sched: work stealing rescues a 4x-slow replica ---------------------
    # finer batches than the paper demo: stealing needs enough remaining
    # range (>= StealConfig.min_batches) to be worth a lease migration
    table = make_numeric_table("events", 1 << 18, 8, batch_rows=1 << 13)

    def replica_coordinator():
        coord = ClusterCoordinator()
        for i in range(4):
            cfg = FabricConfig()
            if i == 3:    # the straggler
                cfg = FabricConfig(rpc_bw=cfg.rpc_bw / 4,
                                   rdma_bw=cfg.rdma_bw / 4)
            coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric(cfg)))
        coord.place_replicas("/data/events", table)
        return coord

    coord = replica_coordinator()
    static = MultiStreamPuller(coord, coord.plan(sql, "/data/events"),
                               schedule="first_ready").run()
    coord = replica_coordinator()
    scheduler = AdaptiveScheduler.default()
    stolen = scheduler.make_puller(coord,
                                   coord.plan(sql, "/data/events")).run()
    print(f"sched: one replica 4x slow — modeled critical path "
          f"{static.modeled_critical_path_s*1e3:.2f} ms static vs "
          f"{stolen.modeled_critical_path_s*1e3:.2f} ms with "
          f"{stolen.steals} steal(s) "
          f"({static.modeled_critical_path_s / stolen.modeled_critical_path_s:.2f}x)")
    for ev in stolen.steal_events:
        print(f"  stole batches [{ev.start_batch}, "
              f"{ev.start_batch + ev.num_batches}) from {ev.victim} "
              f"-> {ev.thief} at t={ev.epoch_s*1e3:.2f} ms")

    # -- sched: shared tickets + lease-boundary preemption ------------------
    sched_gateway = ScanGateway(replica_coordinator(), scheduler=scheduler)
    heavy_sql = ("SELECT " + ", ".join(f"c{i}" for i in range(8))
                 + " FROM events")
    sched_gateway.submit(ScanRequest("trainer", "batch", heavy_sql,
                                     "/data/events", cost_hint=8.0))
    for i in range(3):    # identical dashboards arriving mid-scan coalesce
        sched_gateway.submit(ScanRequest(f"dash{i}", "interactive", sql,
                                         "/data/events", arrival_s=1e-5))
    sched_gateway.run()
    qos = sched_gateway.stats
    print(f"sched: {qos.granted} granted — {qos.ticket_hits} multicast "
          f"ticket hit(s) (one fan-out served {1 + qos.ticket_hits} "
          f"dashboards), {qos.preemptions} preemption(s) parked the heavy "
          f"scan at a lease boundary, {qos.steals} steal(s) mid-query")
    print(sched_table(qos))

    # -- distributed admission: per-server quota shards ---------------------
    # the global budget (4 streams/client, 8 cluster-wide) is split across
    # one shard per server; grants touch only the endpoint's shard
    sharded = ShardedAdmission(
        AdmissionConfig(max_streams_per_client=4, max_streams_total=8),
        [f"s{i}" for i in range(4)])
    # s0's quota slice is 1 and its total-cap slice is 2, so three local
    # acquires borrow 3 units: 2 per-client-quota + 1 total-cap
    for _ in range(3):
        sharded.acquire_stream("trainer", server_id="s0")
    try:                               # the global quota still binds exactly
        for _ in range(2):
            sharded.acquire_stream("trainer", server_id="s1")
    except Backpressure as exc:
        print(f"distributed admission: shard s0 borrowed "
              f"{sharded.stats.borrows} slot(s) from its least-loaded "
              f"peers; global quota denial after "
              f"{sharded.active_streams('trainer')} streams "
              f"(retry after {exc.retry_after_s * 1e3:.1f} ms)")
    for _ in range(3):
        sharded.release_stream("trainer", server_id="s0")
    report = sharded.reconcile(now_s=50e-3)
    print(f"  reconcile returned {report.capacity_returned} borrowed "
          f"slot(s) to their lenders (balanced allocation restored)")

    # a batch client closing streams mid-scan widens an interactive fan-out:
    # the gateway re-plans onto the freed lanes at the modeled release time
    coord = ClusterCoordinator()
    for i in range(4):
        coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric()))
    coord.place_shards("/data/events", engine.catalog.get("/data/events"))
    service = {}
    for closes_mid_scan in (False, True):
        adm = ShardedAdmission(
            AdmissionConfig(max_streams_per_client=4, max_streams_total=4),
            [f"s{i}" for i in range(4)])
        replan_gateway = ScanGateway(coord, admission=adm)
        adm.acquire_stream("trainer", server_id="s0")   # holds half the cap
        adm.acquire_stream("trainer", server_id="s1")
        req = replan_gateway.submit(ScanRequest("dashboard", "interactive",
                                                sql, "/data/events"))
        if closes_mid_scan:
            for sid in ("s0", "s1"):
                adm.release_stream("trainer", server_id=sid, now_s=1e-7)
        replan_gateway.run()
        service[closes_mid_scan] = \
            replan_gateway.result(req.request_id).service_s
    print(f"  re-plan on freed slots: capped fan-out served in "
          f"{service[False]*1e3:.2f} ms; with the batch client closing "
          f"mid-scan {service[True]*1e3:.2f} ms "
          f"({service[False]/service[True]:.2f}x, "
          f"{replan_gateway.stats.replans} replan(s))")
    print(admission_table(sharded.stats))

    # -- stress driver: a seeded population mix, judged for fairness --------
    # interactive lookups ride under a heavy batch class while a Poisson
    # scan storm bursts; the driver submits everything through ONE gateway
    # on ONE modeled clock and attributes every shed/decline causally
    pops = [
        ClientPopulation("interactive", weight=4.0, arrival="uniform",
                         rate_per_beat=3.0, sql=sql, dataset="/data/events",
                         num_streams=2),
        ClientPopulation("batch", weight=1.0, arrival="burst",
                         rate_per_beat=1.0, sql=heavy_sql, cost_hint=8.0,
                         dataset="/data/events", num_streams=2),
        ClientPopulation("storm", weight=2.0, arrival="poisson",
                         rate_per_beat=4.0, sql=heavy_sql, cost_hint=8.0,
                         cost_jitter=0.3, dataset="/data/events",
                         num_streams=2, start_beat=3),
    ]
    stress_coord = ClusterCoordinator(recorder=FlightRecorder())
    for i in range(4):
        stress_coord.add_server(f"s{i}", ThallusServer(Engine(), Fabric()))
    stress_coord.place_replicas("/data/events", table)
    driver = StressDriver(
        ScanGateway(stress_coord, classes=population_classes(pops),
                    modeled_service=True),
        pops, seed=7)
    for _ in range(6):
        driver.beat()
    fair = driver.fairness()
    print(f"stress: {driver.beats} beats, storm active from beat 3 — "
          f"jain={fair['jain']:.3f}, interactive/batch latency inflation "
          f"{fair['latency_inflation']:.2f}x (seeded: replays identically)")
    print(workload_table(driver))


if __name__ == "__main__":
    main()
