"""End-to-end driver: train a reduced LM for a few hundred steps with the
Thallus data plane, checkpoints, and a mid-run crash + resume.

    PYTHONPATH=src python examples/train_lm.py [--arch granite-3-2b]
                                               [--steps 300]

This wraps the production launcher (repro.launch.train); the same command
scales to the full configs on a real mesh.
"""
import subprocess
import sys

ARCH = sys.argv[sys.argv.index("--arch") + 1] if "--arch" in sys.argv else "granite-3-2b"
STEPS = int(sys.argv[sys.argv.index("--steps") + 1]) if "--steps" in sys.argv else 300


def run(extra):
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", ARCH,
           "--reduced", "--seq-len", "128", "--batch-seqs", "8",
           "--ckpt-dir", "artifacts/example_ckpt", "--ckpt-every", "100",
           "--log-every", "25", "--lr", "1e-3"] + extra
    print("+", " ".join(cmd[2:]))
    subprocess.run(cmd, check=True)


def main() -> None:
    half = max(STEPS // 2 // 100 * 100, 100)
    # phase 1: train halfway, then simulate a crash
    run(["--steps", str(STEPS), "--kill-at", str(half)])
    print(f"\n--- simulated node failure at step {half}; relaunching ---\n")
    # phase 2: relaunch — resumes from the latest checkpoint + data cursor
    run(["--steps", str(STEPS)])


if __name__ == "__main__":
    main()
