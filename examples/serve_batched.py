"""Serve a small model with batched requests; responses return as columnar
record batches over the Thallus transport (the serving direction).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.core import Fabric, ThallusTransport
from repro.models import decode, init_params, prefill
from repro.serving import Batcher, Request, completions_to_batch


def main() -> None:
    cfg = get_config("olmoe-1b-7b").reduced()     # tiny MoE, CPU-sized
    params = init_params(cfg, jax.random.PRNGKey(0))

    batcher = Batcher(
        jax.jit(lambda t: prefill(cfg, params, {"tokens": t}, remat="none")),
        jax.jit(lambda c, t, p: decode(cfg, params, c, t, p)),
        batch_size=4)

    rng = np.random.default_rng(7)
    for i in range(10):
        plen = int(rng.integers(4, 12))
        batcher.submit(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=6))

    completions = batcher.run()
    out = completions_to_batch(completions)
    delivered, stats = ThallusTransport(Fabric()).send_batch(out)
    print(f"served {len(completions)} requests "
          f"({delivered.num_rows} tokens) — response batch "
          f"{delivered.nbytes} B over Thallus in {stats.total_s*1e6:.1f} us, "
          f"serialize copies: {stats.serialize_s == 0.0 and 'zero'}")
    for c in completions[:5]:
        print(f"  req {c.request_id}: {c.tokens}")


if __name__ == "__main__":
    main()
