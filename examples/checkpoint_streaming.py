"""Checkpoints ARE record batches: save a train state as a columnar batch,
stream it over the Thallus transport (zero-copy), restore on the "other
side", and verify bit-equality — the paper's protocol applied to the
fault-tolerance path.

    PYTHONPATH=src python examples/checkpoint_streaming.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import Fabric, RpcTransport, ThallusTransport
from repro.training import (TrainConfig, batch_to_state, init_train_state,
                            state_to_batch)


def main() -> None:
    cfg = get_config("zamba2-1.2b").reduced()
    state = init_train_state(cfg, TrainConfig(remat="none"),
                             jax.random.PRNGKey(0))
    batch = state_to_batch(state)
    print(f"train state -> record batch: {batch.num_rows} leaves, "
          f"{batch.nbytes/2**20:.1f} MiB")

    fabric = Fabric()
    for transport in (ThallusTransport(fabric), RpcTransport(fabric)):
        delivered, stats = transport.send_batch(batch)
        restored = batch_to_state(delivered, like=state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print(f"{transport.name:8s} restore OK — transport "
              f"{stats.total_s*1e3:7.3f} ms "
              f"(serialize {stats.serialize_s*1e3:6.3f} ms)")


if __name__ == "__main__":
    main()
